"""Privacy accountant: Theorem 1, Corollary 2, Theorem 4, Proposition 5."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import privacy


BASE = dict(G=5.0, m=1200, tau=1.0 / 1200, p=0.2, sigma=2.0, delta=1e-5)


def test_theorem1_epsilon_formula():
    params = privacy.PrivacyParams(**BASE)
    T, eps_t = 1000, 0.5
    alpha = 2 * math.log(1 / 1e-5) / eps_t + 1
    expected = 4 * alpha * 0.2 * T * (BASE["tau"] * 5.0 / (1200 * 2.0)) ** 2 + eps_t / 2
    assert privacy.epsilon_sdm(params, T, eps_t) == pytest.approx(expected)


def test_sigma_min_precondition():
    params = privacy.PrivacyParams(**{**BASE, "sigma": 0.5})  # sigma^2 < 1/1.25
    assert privacy.epsilon_sdm(params, 100, 0.5) == math.inf


def test_sparsifier_improves_epsilon_by_p():
    """Theorem 1: eps-part scales linearly in p."""
    eps_t = 0.4
    e_small = privacy.epsilon_sdm(privacy.PrivacyParams(**{**BASE, "p": 0.1}), 500, eps_t)
    e_big = privacy.epsilon_sdm(privacy.PrivacyParams(**{**BASE, "p": 0.2}), 500, eps_t)
    assert (e_big - eps_t / 2) == pytest.approx(2.0 * (e_small - eps_t / 2))


def test_proposition5_p_squared_gap():
    """Reversed design is worse by exactly 1/p^2 in the eps-part (§4.3)."""
    params = privacy.PrivacyParams(**BASE)
    T, eps_t = 300, 0.3
    sdm = privacy.epsilon_sdm(params, T, eps_t) - eps_t / 2
    alt = privacy.epsilon_alternative(params, T, eps_t) - eps_t / 2
    assert alt / sdm == pytest.approx(1.0 / params.p ** 2, rel=1e-6)


def test_corollary2_sigma_inverts_theorem1():
    """Running Theorem 1 with Corollary 2's sigma recovers ~eps (tau=1/m)."""
    G, m, p, T, eps, delta = 5.0, 300, 0.2, 200_000, 0.05, 1e-5
    sigma = privacy.sigma_for_budget(G, m, p, T, eps, delta)
    assert sigma ** 2 >= privacy.SIGMA_SQ_MIN
    params = privacy.PrivacyParams(G=G, m=m, tau=1.0 / m, p=p, sigma=sigma,
                                   delta=delta)
    # eps_total = 4 alpha p T (G/(m^2 sigma))^2 + eps/2 with Cor-2 sigma
    # == eps^2/(2 log(1/delta)+eps) * alpha/2 ... verify it is close to eps.
    total = privacy.epsilon_sdm(params, T, eps)
    assert total == pytest.approx(eps, rel=0.01)


@given(G=st.floats(0.5, 20.0), m=st.integers(100, 3000),
       p=st.floats(0.05, 1.0), T=st.integers(1000, 500_000),
       eps=st.floats(0.01, 2.0))
@settings(max_examples=100, deadline=None)
def test_sigma_epsilon_inversion_round_trips_exactly(G, m, p, T, eps):
    """sigma_sq_for_epsilon is the EXACT inverse of Theorem 1: feeding
    Corollary 2's sigma back through epsilon_sdm reproduces the budget
    to float round-off (both sides now share the one _theorem1_K
    coefficient, so there is no second formula to drift)."""
    sigma_sq = privacy.sigma_sq_for_epsilon(
        G=G, m=m, tau=1.0 / m, p=p, T=T, eps=eps, delta=1e-5)
    sigma = math.sqrt(sigma_sq)
    if sigma_sq < privacy.SIGMA_SQ_MIN:
        # below the Gaussian-mechanism precondition sigma_for_budget
        # raises (or clamps); epsilon_sdm would return inf.
        with pytest.raises(ValueError, match="sigma"):
            privacy.sigma_for_budget(G, m, p, T, eps, 1e-5)
        return
    assert privacy.sigma_for_budget(G, m, p, T, eps, 1e-5) == \
        pytest.approx(sigma, rel=1e-12)
    params = privacy.PrivacyParams(G=G, m=m, tau=1.0 / m, p=p,
                                   sigma=sigma, delta=1e-5)
    assert privacy.epsilon_sdm(params, T, eps) == pytest.approx(
        eps, rel=1e-9)


def test_sigma_for_budget_clamp_path_spends_at_most_eps():
    """When the exact sigma falls below SIGMA_SQ_MIN, clamp=True raises
    it to the floor — which can only DECREASE the spent epsilon."""
    kw = dict(G=5.0, m=10_000, p=0.2, T=10, eps=1.0)
    sigma = privacy.sigma_for_budget(**kw, clamp=True)
    assert sigma ** 2 == pytest.approx(privacy.SIGMA_SQ_MIN)
    sigma_sq_exact = privacy.sigma_sq_for_epsilon(
        G=kw["G"], m=kw["m"], tau=1.0 / kw["m"], p=kw["p"], T=kw["T"],
        eps=kw["eps"], delta=1e-5)
    assert sigma ** 2 >= sigma_sq_exact     # clamp only ever RAISES sigma
    # Theorem 1 spends eps/2 * (1 + sigma_sq_exact / sigma^2) at the
    # clamped sigma (T*K/sigma_exact^2 == eps/2 by exact inversion)
    spent = kw["eps"] / 2.0 * (1.0 + sigma_sq_exact / sigma ** 2)
    assert spent <= kw["eps"]


def test_corollary2_raises_when_infeasible():
    with pytest.raises(ValueError):
        privacy.sigma_for_budget(G=5.0, m=10_000, p=0.2, T=10, eps=1.0)


def test_theorem4_m4_scaling():
    """T_max = O(m^4): doubling m multiplies the budget by 16."""
    t1 = privacy.max_iterations(G=5.0, m=100, p=0.2, eps=1.0)
    t2 = privacy.max_iterations(G=5.0, m=200, p=0.2, eps=1.0)
    assert t2 / t1 == pytest.approx(16.0, rel=0.01)


def test_accountant_tracks_composition():
    params = privacy.PrivacyParams(**BASE)
    acc = privacy.PrivacyAccountant(params, eps_target=0.5)
    acc.step(1000)
    assert acc.steps == 1000
    # Lemma 4 conversion with alpha - 1 = 2 log(1/delta)/eps gives exactly
    # rho*T + eps/2, matching Theorem 1.
    assert acc.epsilon == pytest.approx(privacy.epsilon_sdm(params, 1000, 0.5))


@pytest.mark.parametrize("bad", [0.0, -1.0])
def test_params_reject_nonpositive_sigma(bad):
    with pytest.raises(ValueError, match="sigma"):
        privacy.PrivacyParams(**{**BASE, "sigma": bad})


@pytest.mark.parametrize("bad", [0.0, -0.1, 1.5])
def test_params_reject_p_outside_unit(bad):
    with pytest.raises(ValueError, match="p must be"):
        privacy.PrivacyParams(**{**BASE, "p": bad})


def test_params_reject_bad_scale_inputs():
    with pytest.raises(ValueError, match="G"):
        privacy.PrivacyParams(**{**BASE, "G": 0.0})
    with pytest.raises(ValueError, match="m"):
        privacy.PrivacyParams(**{**BASE, "m": 0})
    with pytest.raises(ValueError, match="delta"):
        privacy.PrivacyParams(**{**BASE, "delta": 0.0})


@pytest.mark.parametrize("bad_eps", [0.0, -0.5])
def test_eps_target_must_be_positive(bad_eps):
    params = privacy.PrivacyParams(**BASE)
    with pytest.raises(ValueError, match="eps_target"):
        privacy.rdp_alpha(bad_eps, 1e-5)
    with pytest.raises(ValueError, match="eps_target"):
        privacy.epsilon_sdm(params, 100, bad_eps)
    with pytest.raises(ValueError, match="eps_target"):
        privacy.PrivacyAccountant(params, eps_target=bad_eps)


def test_sigma_for_budget_rejects_bad_inputs():
    good = dict(G=5.0, m=300, p=0.2, T=200_000, eps=0.05)
    with pytest.raises(ValueError, match="eps_target"):
        privacy.sigma_for_budget(**{**good, "eps": 0.0})
    with pytest.raises(ValueError, match="p must be"):
        privacy.sigma_for_budget(**{**good, "p": 1.5})
    with pytest.raises(ValueError, match="G"):
        privacy.sigma_for_budget(**{**good, "G": -1.0})
    with pytest.raises(ValueError, match="T"):
        privacy.sigma_for_budget(**{**good, "T": 0})
    with pytest.raises(ValueError, match="p must be"):
        privacy.max_iterations(G=5.0, m=100, p=0.0, eps=1.0)


@given(p=st.floats(0.01, 1.0), T=st.integers(1, 10_000),
       sigma=st.floats(1.0, 50.0))
@settings(max_examples=100, deadline=None)
def test_epsilon_monotonicity_properties(p, T, sigma):
    """eps grows with T and p, shrinks with sigma (Remark 2)."""
    mk = lambda **kw: privacy.PrivacyParams(**{**BASE, "sigma": sigma, "p": p, **kw})
    e = privacy.epsilon_sdm(mk(), T, 0.5)
    assert e >= 0.25  # >= eps_target / 2
    assert privacy.epsilon_sdm(mk(), T + 100, 0.5) >= e
    if sigma + 1.0 <= 50.0:
        assert privacy.epsilon_sdm(mk(sigma=sigma + 1.0), T, 0.5) <= e


@given(m=st.integers(50, 5000))
@settings(max_examples=50, deadline=None)
def test_theorem4_beats_m2_prior_art(m):
    """The paper's T=O(m^4) dominates the O(m^2) state of the art for large m."""
    t_paper = privacy.max_iterations(G=5.0, m=m, p=0.2, eps=1.0)
    t_prior = m ** 2
    if m >= 500:
        assert t_paper > t_prior


# ---- participation amplification (partial participation, q < 1) ----------

@pytest.mark.parametrize("bad_q", [0.0, -0.3, 1.0001, 2.0])
def test_participation_q_outside_unit_interval_rejected(bad_q):
    with pytest.raises(ValueError, match="participation_q must be"):
        privacy.PrivacyParams(**BASE, participation_q=bad_q)


def test_participation_q_one_is_identity():
    """q=1 (full participation, the default) changes nothing."""
    base = privacy.PrivacyParams(**BASE)
    full = privacy.PrivacyParams(**BASE, participation_q=1.0)
    T, eps_t = 700, 0.5
    assert privacy.epsilon_sdm(full, T, eps_t) == \
        privacy.epsilon_sdm(base, T, eps_t)


@pytest.mark.parametrize("q", [0.1, 0.5, 0.8])
def test_participation_amplification_is_quadratic(q):
    """Subsampled-RDP composition: the eps-part scales with q^2 (the
    participation fraction multiplies the effective subsampling rate
    q*tau, and the per-step RDP is quadratic in the rate)."""
    T, eps_t = 500, 0.4
    e_full = privacy.epsilon_sdm(privacy.PrivacyParams(**BASE), T, eps_t)
    e_part = privacy.epsilon_sdm(
        privacy.PrivacyParams(**BASE, participation_q=q), T, eps_t)
    assert (e_part - eps_t / 2) == \
        pytest.approx(q ** 2 * (e_full - eps_t / 2), rel=1e-9)
    assert e_part < e_full          # strictly amplified


def test_accountant_tracks_amplified_epsilon():
    acct_full = privacy.PrivacyAccountant(
        privacy.PrivacyParams(**BASE), eps_target=1.0)
    acct_part = privacy.PrivacyAccountant(
        privacy.PrivacyParams(**BASE, participation_q=0.5), eps_target=1.0)
    for _ in range(50):
        acct_full.step()
        acct_part.step()
    assert acct_part.epsilon < acct_full.epsilon


def test_from_compressor_passes_participation_q_through():
    from repro.core import compressor
    comp = compressor.make("bernoulli", p=0.2)
    params = privacy.PrivacyParams.from_compressor(
        comp, G=5.0, m=1200, tau=1 / 1200, sigma=2.0, participation_q=0.7)
    assert params.participation_q == 0.7
    assert params.p == comp.release_probability
