"""ParamPlane (repro.core.plane): the wire-plane flatten/unflatten spec.

Covers the tentpole's correctness surface:
  * pack/unpack round-trip across mixed dtypes / ranks / padding,
    property-tested (hypothesis; offline fallback in hermetic runs),
  * bucket assignment by sharding key (default flat bucket vs TP buckets
    whose lane IS the sharded trailing dim), incl. the steps.py
    ``bucket_keys_from_axes`` policy,
  * bit-equality of plane-granular compressor draws with the historical
    per-leaf path on single-leaf lane-multiple trees (same key, same
    element count -> identical threefry stream),
  * spec caching/hashability (safe to close over in jit) and the
    stacked (vmapped) variants the reference executors use.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compressor, plane, sdm_dsgd, sparsifier

LANE = plane.LANE


# ---------------------------------------------------------------------------
# Round-trip property.
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n_leaves=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2 ** 16),
    lane=st.sampled_from([8, 128, 1024]),
    row_multiple=st.sampled_from([1, 4]),
)
def test_pack_unpack_roundtrip_property(n_leaves, seed, lane, row_multiple):
    rng = np.random.default_rng(seed)
    dtypes = [jnp.float32, jnp.bfloat16, jnp.float16]
    tree = {}
    for i in range(n_leaves):
        rank = int(rng.integers(1, 4))
        shape = tuple(int(rng.integers(1, 9)) for _ in range(rank))
        dt = dtypes[int(rng.integers(0, len(dtypes)))]
        tree[f"leaf{i}"] = jnp.asarray(
            rng.normal(size=shape), jnp.float32).astype(dt)
    spec = plane.ParamPlane.for_tree(tree, lane=lane,
                                     row_multiple=row_multiple)
    planes = spec.pack(tree)
    # geometry: padded rows, row_multiple respected, zero pad
    total = sum(int(v.size) for v in tree.values())
    assert spec.total_size == total
    for p, b in zip(planes, spec.buckets):
        assert p.shape == (b.rows, b.lane) and p.dtype == jnp.float32
        assert b.rows % row_multiple == 0
        flat = np.asarray(p).reshape(-1)
        np.testing.assert_array_equal(flat[b.size:], 0.0)
    back = spec.unpack(planes)
    for k, v in tree.items():
        assert back[k].dtype == v.dtype and back[k].shape == v.shape
        # f32 leaves are exact; low-precision leaves round-trip through
        # f32 losslessly as well (f32 is a superset)
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(v, np.float32))


def test_stacked_pack_unpack_matches_per_node():
    rng = np.random.default_rng(0)
    tree = {"a": jnp.asarray(rng.normal(size=(4, 3, 5)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(4, 7)), jnp.float32)}
    spec = plane.ParamPlane.for_stacked(tree)
    stacked = spec.pack_stacked(tree)
    for i in range(4):
        per_node = spec.pack(jax.tree.map(lambda v: v[i], tree))
        for s_, p_ in zip(stacked, per_node):
            np.testing.assert_array_equal(np.asarray(s_[i]), np.asarray(p_))
    back = spec.unpack_stacked(stacked)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


# ---------------------------------------------------------------------------
# Bucket assignment by sharding key.
# ---------------------------------------------------------------------------

def test_bucket_assignment_by_key():
    tree = {"dense1": jnp.zeros((4, 16)), "tp1": jnp.zeros((8, 32)),
            "tp2": jnp.zeros((2, 3, 32)), "dense2": jnp.zeros((5,)),
            "tp_other": jnp.zeros((4, 64))}
    keys = {"dense1": None, "tp1": ("model", 32), "tp2": ("model", 32),
            "dense2": None, "tp_other": ("model", 64)}
    spec = plane.ParamPlane.for_tree(tree, buckets=keys)
    assert spec.n_buckets == 3
    by_key = {b.key: b for b in spec.buckets}
    flat = by_key[None]
    assert flat.lane == LANE and flat.size == 4 * 16 + 5
    tp32 = by_key[("model", 32)]
    # TP bucket: lane IS the shared trailing dim, rows = stacked rows
    assert tp32.lane == 32 and tp32.shape == (8 + 6, 32)
    assert by_key[("model", 64)].shape == (4, 64)
    # pack keeps TP rows contiguous and round-trips
    planes = spec.pack(tree)
    back = spec.unpack(planes)
    assert jax.tree.map(lambda v: v.shape, back) == \
        jax.tree.map(lambda v: v.shape, tree)


def test_bucket_keys_from_axes_policy():
    axes = {"wq": ("embed", "heads"), "emb": ("vocab", "embed"),
            "bias": ("mlp",), "scale": ()}
    shapes = {"wq": (16, 8), "emb": (100, 16), "bias": (32,), "scale": ()}
    mapping = {"heads": "model", "mlp": "model", "vocab": "model",
               "embed": None}
    keys = plane.bucket_keys_from_axes(axes, shapes, mapping)
    assert keys["wq"] == ("model", 8)       # trailing axis model-sharded
    assert keys["emb"] is None              # trailing axis unsharded
    assert keys["bias"] == ("model", 32)
    assert keys["scale"] is None


def test_use_buckets_context_scopes_for_tree():
    tree = {"a": jnp.zeros((4, 8)), "b": jnp.zeros((3,))}
    keys = {"a": ("model", 8), "b": None}
    spec_flat = plane.ParamPlane.for_tree(tree)
    assert spec_flat.n_buckets == 1
    with plane.use_buckets(keys):
        spec_tp = plane.ParamPlane.for_tree(tree)
        assert spec_tp.n_buckets == 2
    # context popped: back to the flat default (and cached specs distinct)
    assert plane.ParamPlane.for_tree(tree) is spec_flat
    assert spec_tp is not spec_flat


def test_spec_is_cached_and_hashable():
    tree = {"a": jnp.zeros((4, 8))}
    s1 = plane.ParamPlane.for_tree(tree)
    s2 = plane.ParamPlane.for_tree({"a": jnp.ones((4, 8))})
    assert s1 is s2             # same treedef/shapes/dtypes -> same spec
    hash(s1)                    # closable over in jit/shard_map
    assert plane.ParamPlane.for_tree(tree, lane=64) is not s1


# ---------------------------------------------------------------------------
# Bit-equality with the per-leaf draw on single-leaf lane-multiple trees.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_name", ["bernoulli", "fixedk", "rows"])
def test_single_leaf_lane_multiple_draws_bit_equal(spec_name):
    """On a single-leaf tree whose size is a LANE multiple the plane is
    a pure reshape, so the plane-granular compressor draw must be
    BIT-EQUAL to compressing the leaf directly (same key, same element
    count -> identical threefry stream). This pins the PR-5 trajectory
    break to exactly the padded-draw granularity, nothing else."""
    d = 4 * LANE
    x = jnp.asarray(np.random.default_rng(3).normal(size=(d,)), jnp.float32)
    comp = compressor.make(spec_name, p=0.25)
    key = jax.random.PRNGKey(11)
    spec = plane.ParamPlane.for_tree({"w": x})
    (pl,) = spec.pack({"w": x})
    via_plane = spec.unpack(
        (comp.decompress(comp.compress(key, pl)),))["w"]
    if spec_name == "rows":
        # rows blocks differ between a (d,) leaf (rows of 1 elem) and
        # the (4, LANE) plane — compare against the plane-shaped leaf
        direct = comp.decompress(
            comp.compress(key, x.reshape(4, LANE))).reshape(-1)
    else:
        direct = comp.decompress(comp.compress(key, x))
    np.testing.assert_array_equal(np.asarray(via_plane),
                                  np.asarray(direct.reshape(-1)))


def test_plane_distributed_state_shapes():
    """init_distributed_state carries s/d (and replica xhat) as planes."""
    params = {"a": jnp.ones((9, 5)), "b": jnp.zeros((40,))}
    st = sdm_dsgd.init_distributed_state(params, self_weight=1.0 / 3.0)
    spec = plane.ParamPlane.for_tree(params)
    assert isinstance(st.s, tuple) and len(st.s) == spec.n_buckets
    (rows, lane), = spec.plane_shapes()
    assert st.s[0].shape == (rows, lane) and st.d[0].shape == (rows, lane)
    # s0 = (1 - W_ii) x0 on the plane, pad included (zeros stay zero)
    xp = spec.pack(params)[0]
    np.testing.assert_allclose(np.asarray(st.s[0]),
                               np.asarray((1 - 1.0 / 3.0) * xp), rtol=1e-6)
    st_r = sdm_dsgd.init_distributed_state(params, 0.5, n_replicas=3)
    assert st_r.xhat[0].shape == (3, rows, lane)


def test_wire_shape_tree_accounting_surface():
    params = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((37,))}
    wire = sdm_dsgd.wire_shape_tree(params)
    assert [tuple(w.shape) for w in wire] == [(2, LANE)]
    # one num_kept ceil over the whole plane — the round-once convention
    cfg = sdm_dsgd.SDMConfig(p=0.21, mode="fixedk_packed")
    assert sdm_dsgd.transmitted_elements_per_step(params, cfg) == \
        sparsifier.num_kept(2 * LANE, 0.21)


# ---------------------------------------------------------------------------
# Fused single-buffer QSGD ("qsgdf") on planes: bit-equal to unfused qsgd.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
def test_fused_qsgd_plane_bitequal_unfused(bits):
    """On a lane-multiple plane the fused qsgdf pipeline (one pallas
    quantize+pack launch, norm embedded as 4 tail bytes, ONE u8 wire
    leaf) must decompress BIT-EQUAL to the unfused qsgd (values, scale)
    pair under the same key — the launch/permute savings are format-
    only, never a trajectory change."""
    d = 6 * plane.LANE
    x = jnp.asarray(np.random.default_rng(7).normal(size=(d,)), jnp.float32)
    spec = plane.ParamPlane.for_tree({"w": x})
    (pl,) = spec.pack({"w": x})
    key = jax.random.PRNGKey(31)
    fused = compressor.make(f"qsgdf:{bits}", p=1.0)
    plain = compressor.make(f"qsgd:{bits}", p=1.0)
    fp = fused.compress(key, pl)
    # single wire leaf: packed bytes + 4 norm-bitcast tail bytes
    assert fp.scale is None
    assert fp.values.shape == (d // (8 // bits if bits in (2, 4) else 1) + 4,)
    np.testing.assert_array_equal(
        np.asarray(fused.decompress(fp)),
        np.asarray(plain.decompress(plain.compress(key, pl))))


def test_fused_qsgd_vmap_over_nodes_bitequal():
    """vmapped per-node compress (the stacked reference path) stays
    bit-equal to the per-node loop."""
    n, rows = 4, 8
    x = jnp.asarray(np.random.default_rng(13).normal(
        size=(n, rows, plane.LANE)), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(3), n)
    comp = compressor.make("qsgdf:4", p=1.0)
    vals = jax.vmap(lambda k, xi: comp.compress(k, xi).values)(keys, x)
    for i in range(n):
        np.testing.assert_array_equal(
            np.asarray(vals[i]),
            np.asarray(comp.compress(keys[i], x[i]).values))
