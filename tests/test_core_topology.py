"""Topology / consensus-matrix properties (paper §4.2 requirements)."""
import numpy as np
import pytest

from repro.core import topology, theory


TOPOS = {
    "ring8": topology.ring(8),
    "ring50": topology.ring(50),
    "torus4x4": topology.torus_2d(4, 4),
    "complete8": topology.complete(8),
    "star6": topology.star(6),
    "er50": topology.erdos_renyi(50, 0.35, seed=0),
}


@pytest.mark.parametrize("name", sorted(TOPOS))
def test_consensus_matrix_properties(name):
    topo = TOPOS[name]
    w = topo.weights
    # 1) doubly stochastic
    np.testing.assert_allclose(w.sum(axis=0), 1.0, atol=1e-8)
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-8)
    # 2) symmetric
    np.testing.assert_allclose(w, w.T, atol=1e-10)
    # spectrum in (-1, 1] with a single unit eigenvalue (connected graph)
    ev = topo.eigenvalues
    assert ev[0] == pytest.approx(1.0, abs=1e-8)
    assert ev[1] < 1.0 - 1e-10
    assert ev[-1] > -1.0
    assert 0.0 <= topo.beta < 1.0


def test_er_graph_matches_paper_construction():
    """W = I - 2/(3 lambda_max(L)) L for the ER experimental graph."""
    topo = TOPOS["er50"]
    deg = np.diag(topo.adjacency.sum(axis=1))
    lap = deg - topo.adjacency
    lam_max = np.max(np.linalg.eigvalsh(lap))
    expected = np.eye(50) - 2.0 / (3.0 * lam_max) * lap
    np.testing.assert_allclose(topo.weights, expected, atol=1e-12)


def test_ring_neighbors():
    topo = TOPOS["ring8"]
    assert set(topo.neighbors(0)) == {1, 7}
    assert set(topo.neighbors(3)) == {2, 4}


def test_complete_beta_zero():
    assert TOPOS["complete8"].beta == pytest.approx(0.0, abs=1e-8)


def test_mixed_with_theta_spectrum():
    """W_theta = (1-theta)I + theta W keeps double stochasticity; Lemma 6."""
    topo = TOPOS["ring8"]
    theta = 0.6
    w_th = topo.mixed_with_theta(theta)
    np.testing.assert_allclose(w_th.sum(axis=1), 1.0, atol=1e-10)
    ev = np.sort(np.linalg.eigvalsh(w_th))[::-1]
    beta_th = max(abs(ev[1]), abs(ev[-1]))
    # Lemma 6: 1/(1-beta_theta) <= 1/(theta (1-beta))
    assert 1.0 / (1.0 - beta_th) <= 1.0 / (theta * (1.0 - topo.beta)) + 1e-9


def test_dcdsgd_threshold_monotone():
    """Remark 1: the DC-DSGD p-threshold; worse (higher) as lambda_n -> -1."""
    ths = [theory.dcdsgd_min_p(ln) for ln in (-0.9, -0.5, 0.0, 0.5)]
    assert all(0 < t < 1 for t in ths)
    assert ths == sorted(ths, reverse=True)
    # p = 0.2 is below the threshold for typical graphs -> DC-DSGD invalid
    assert theory.dcdsgd_min_p(TOPOS["er50"].lambda_n) > 0.2


# ---------------------------------------------------------------------------
# Schedule-aware placement (ICI ring hop minimization).
# ---------------------------------------------------------------------------

def test_placement_cost_ring_is_zero():
    """Every ring edge lands on physically adjacent devices: 0 extra hops."""
    assert topology.placement_cost(TOPOS["ring8"].adjacency) == 0
    # and greedy never leaves the optimum
    order = topology.greedy_placement(TOPOS["ring8"])
    assert topology.placement_cost(TOPOS["ring8"].adjacency, order) == 0


@pytest.mark.parametrize("topo_fn", [
    lambda: topology.ring(8),
    lambda: topology.torus_2d(2, 4),
    lambda: topology.torus_2d(4, 4),
    lambda: topology.erdos_renyi(10, 0.35, seed=1),
    lambda: topology.star(8),
    lambda: topology.directed_ring(8),
])
def test_greedy_placement_never_increases_hops(topo_fn):
    """The ISSUE's contract: greedy renumbering is monotone — hop count
    never increases vs the identity placement, on any graph."""
    topo = topo_fn()
    identity = topology.placement_cost(topo.adjacency)
    order = topology.greedy_placement(topo)
    assert topology.placement_cost(topo.adjacency, order) <= identity


def test_greedy_placement_recovers_shuffled_ring():
    """A randomly renumbered ring costs extra hops; greedy must find a
    placement at (or near) the physical-ring optimum of zero."""
    rng = np.random.default_rng(3)
    shuffled = topology.apply_placement(topology.ring(8), rng.permutation(8))
    assert topology.placement_cost(shuffled.adjacency) > 0
    order = topology.greedy_placement(shuffled)
    assert topology.placement_cost(shuffled.adjacency, order) == 0


def test_apply_placement_preserves_spectrum_and_validity():
    topo = topology.torus_2d(2, 4)
    order = np.random.default_rng(0).permutation(8)
    placed = topology.apply_placement(topo, order)   # __post_init__ validates
    np.testing.assert_allclose(placed.eigenvalues, topo.eigenvalues,
                               atol=1e-9)
    assert placed.beta == pytest.approx(topo.beta)
    # the edge (i, j) maps to (order[i], order[j])
    adj = np.asarray(topo.adjacency)
    padj = np.asarray(placed.adjacency)
    for i in range(8):
        for j in range(8):
            assert padj[order[i], order[j]] == adj[i, j]


def test_placement_cost_rejects_non_permutation():
    with pytest.raises(ValueError, match="permutation"):
        topology.placement_cost(TOPOS["ring8"].adjacency,
                                np.array([0, 1, 1, 3, 4, 5, 6, 7]))


# ---- placement-aware schedule compilation (train.steps wiring) ------------

def test_placed_schedule_preserves_spectrum():
    """``gossip.sequence_by_name(..., placement=True)`` — the path
    ``train.steps._compiled_schedule`` compiles through — must renumber
    without touching the mixing spectrum: apply_placement is a
    permutation-similarity, so every round's dense W keeps its
    eigenvalues, and the hop cost never exceeds the identity placement."""
    from repro.core import gossip

    for spec in ("er:0.5", "star", "matchings:3"):
        plain = gossip.sequence_by_name(spec, 8, seed=3)
        placed = gossip.sequence_by_name(spec, 8, seed=3, placement=True)
        assert placed.length == plain.length
        for s_plain, s_placed in zip(plain.schedules, placed.schedules):
            ev_plain = np.sort(np.linalg.eigvals(s_plain.dense_weights()))
            ev_placed = np.sort(np.linalg.eigvals(s_placed.dense_weights()))
            np.testing.assert_allclose(ev_placed.real, ev_plain.real,
                                       atol=1e-9)
            np.testing.assert_allclose(ev_placed.imag, ev_plain.imag,
                                       atol=1e-9)


def test_placed_schedule_never_costs_more_hops():
    from repro.core import gossip

    for spec, n in (("er:0.4", 10), ("star", 8)):
        plain = gossip.sequence_by_name(spec, n, seed=7)
        placed = gossip.sequence_by_name(spec, n, seed=7, placement=True)
        cost = lambda seq: sum(
            topology.placement_cost(
                (np.abs(s.dense_weights() - np.diag(np.diag(
                    s.dense_weights()))) > 0).astype(np.int64))
            for s in seq.schedules)
        assert cost(placed) <= cost(plain)


def test_ring_placement_is_noop():
    """The ring is already hop-optimal: placement must keep it
    byte-identical (greedy only applies a strictly better order)."""
    from repro.core import gossip

    plain = gossip.sequence_by_name("ring", 8)
    placed = gossip.sequence_by_name("ring", 8, placement=True)
    assert placed.schedules == plain.schedules


# ---- masked participation subgraphs (edge-fleet simulator) ----------------

def test_masked_subgraph_full_participation_is_identity():
    topo = TOPOS["ring8"]
    sub = topology.masked_subgraph(topo, range(8))
    np.testing.assert_array_equal(sub.adjacency, topo.adjacency)
    # byte-identical weights: no-fault rounds must mix exactly like the
    # base graph (NOT a recomputed Metropolis-Hastings reweighting)
    np.testing.assert_array_equal(sub.weights, topo.weights)


def test_masked_subgraph_isolates_inactive_rows():
    topo = TOPOS["ring8"]
    sub = topology.masked_subgraph(topo, [0, 1, 2, 5])
    w = np.asarray(sub.weights)
    # inactive nodes: identity rows/cols (they keep their own state)
    for i in (3, 4, 6, 7):
        e = np.zeros(8)
        e[i] = 1.0
        np.testing.assert_allclose(w[i], e, atol=1e-12)
        np.testing.assert_allclose(w[:, i], e, atol=1e-12)
    # still a valid consensus matrix on the induced graph
    np.testing.assert_allclose(w.sum(axis=0), 1.0, atol=1e-9)
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-9)
    np.testing.assert_allclose(w, w.T, atol=1e-12)
    # no active-inactive edges survive
    adj = np.asarray(sub.adjacency)
    assert adj[0, 7] == 0 and adj[2, 3] == 0
    assert adj[1, 0] == 1 and adj[1, 2] == 1


def test_masked_subgraph_directed_column_stochastic():
    topo = topology.directed_ring(6)
    sub = topology.masked_subgraph(topo, [0, 1, 2, 3])
    w = np.asarray(sub.weights)
    np.testing.assert_allclose(w.sum(axis=0), 1.0, atol=1e-9)
    for i in (4, 5):
        assert w[i, i] == pytest.approx(1.0)
