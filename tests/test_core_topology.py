"""Topology / consensus-matrix properties (paper §4.2 requirements)."""
import numpy as np
import pytest

from repro.core import topology, theory


TOPOS = {
    "ring8": topology.ring(8),
    "ring50": topology.ring(50),
    "torus4x4": topology.torus_2d(4, 4),
    "complete8": topology.complete(8),
    "star6": topology.star(6),
    "er50": topology.erdos_renyi(50, 0.35, seed=0),
}


@pytest.mark.parametrize("name", sorted(TOPOS))
def test_consensus_matrix_properties(name):
    topo = TOPOS[name]
    w = topo.weights
    # 1) doubly stochastic
    np.testing.assert_allclose(w.sum(axis=0), 1.0, atol=1e-8)
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-8)
    # 2) symmetric
    np.testing.assert_allclose(w, w.T, atol=1e-10)
    # spectrum in (-1, 1] with a single unit eigenvalue (connected graph)
    ev = topo.eigenvalues
    assert ev[0] == pytest.approx(1.0, abs=1e-8)
    assert ev[1] < 1.0 - 1e-10
    assert ev[-1] > -1.0
    assert 0.0 <= topo.beta < 1.0


def test_er_graph_matches_paper_construction():
    """W = I - 2/(3 lambda_max(L)) L for the ER experimental graph."""
    topo = TOPOS["er50"]
    deg = np.diag(topo.adjacency.sum(axis=1))
    lap = deg - topo.adjacency
    lam_max = np.max(np.linalg.eigvalsh(lap))
    expected = np.eye(50) - 2.0 / (3.0 * lam_max) * lap
    np.testing.assert_allclose(topo.weights, expected, atol=1e-12)


def test_ring_neighbors():
    topo = TOPOS["ring8"]
    assert set(topo.neighbors(0)) == {1, 7}
    assert set(topo.neighbors(3)) == {2, 4}


def test_complete_beta_zero():
    assert TOPOS["complete8"].beta == pytest.approx(0.0, abs=1e-8)


def test_mixed_with_theta_spectrum():
    """W_theta = (1-theta)I + theta W keeps double stochasticity; Lemma 6."""
    topo = TOPOS["ring8"]
    theta = 0.6
    w_th = topo.mixed_with_theta(theta)
    np.testing.assert_allclose(w_th.sum(axis=1), 1.0, atol=1e-10)
    ev = np.sort(np.linalg.eigvalsh(w_th))[::-1]
    beta_th = max(abs(ev[1]), abs(ev[-1]))
    # Lemma 6: 1/(1-beta_theta) <= 1/(theta (1-beta))
    assert 1.0 / (1.0 - beta_th) <= 1.0 / (theta * (1.0 - topo.beta)) + 1e-9


def test_dcdsgd_threshold_monotone():
    """Remark 1: the DC-DSGD p-threshold; worse (higher) as lambda_n -> -1."""
    ths = [theory.dcdsgd_min_p(ln) for ln in (-0.9, -0.5, 0.0, 0.5)]
    assert all(0 < t < 1 for t in ths)
    assert ths == sorted(ths, reverse=True)
    # p = 0.2 is below the threshold for typical graphs -> DC-DSGD invalid
    assert theory.dcdsgd_min_p(TOPOS["er50"].lambda_n) > 0.2


# ---------------------------------------------------------------------------
# Schedule-aware placement (ICI ring hop minimization).
# ---------------------------------------------------------------------------

def test_placement_cost_ring_is_zero():
    """Every ring edge lands on physically adjacent devices: 0 extra hops."""
    assert topology.placement_cost(TOPOS["ring8"].adjacency) == 0
    # and greedy never leaves the optimum
    order = topology.greedy_placement(TOPOS["ring8"])
    assert topology.placement_cost(TOPOS["ring8"].adjacency, order) == 0


@pytest.mark.parametrize("topo_fn", [
    lambda: topology.ring(8),
    lambda: topology.torus_2d(2, 4),
    lambda: topology.torus_2d(4, 4),
    lambda: topology.erdos_renyi(10, 0.35, seed=1),
    lambda: topology.star(8),
    lambda: topology.directed_ring(8),
])
def test_greedy_placement_never_increases_hops(topo_fn):
    """The ISSUE's contract: greedy renumbering is monotone — hop count
    never increases vs the identity placement, on any graph."""
    topo = topo_fn()
    identity = topology.placement_cost(topo.adjacency)
    order = topology.greedy_placement(topo)
    assert topology.placement_cost(topo.adjacency, order) <= identity


def test_greedy_placement_recovers_shuffled_ring():
    """A randomly renumbered ring costs extra hops; greedy must find a
    placement at (or near) the physical-ring optimum of zero."""
    rng = np.random.default_rng(3)
    shuffled = topology.apply_placement(topology.ring(8), rng.permutation(8))
    assert topology.placement_cost(shuffled.adjacency) > 0
    order = topology.greedy_placement(shuffled)
    assert topology.placement_cost(shuffled.adjacency, order) == 0


def test_apply_placement_preserves_spectrum_and_validity():
    topo = topology.torus_2d(2, 4)
    order = np.random.default_rng(0).permutation(8)
    placed = topology.apply_placement(topo, order)   # __post_init__ validates
    np.testing.assert_allclose(placed.eigenvalues, topo.eigenvalues,
                               atol=1e-9)
    assert placed.beta == pytest.approx(topo.beta)
    # the edge (i, j) maps to (order[i], order[j])
    adj = np.asarray(topo.adjacency)
    padj = np.asarray(placed.adjacency)
    for i in range(8):
        for j in range(8):
            assert padj[order[i], order[j]] == adj[i, j]


def test_placement_cost_rejects_non_permutation():
    with pytest.raises(ValueError, match="permutation"):
        topology.placement_cost(TOPOS["ring8"].adjacency,
                                np.array([0, 1, 1, 3, 4, 5, 6, 7]))
