"""Distributed (shard_map + ppermute) executors == stacked reference
executors, bit-close, for EVERY registered method — one table-driven
sweep over methods x topologies x {dense, packed} payloads
(tests/helpers/method_parity_check.py holds the case table).

Runs in subprocesses because XLA_FLAGS device-count faking must happen
before jax initializes (the main test process keeps 1 device).

Coverage per group:
  sdm_core      — the historical regression anchor: SDM-DSGD on
                  ring/torus/ER/star, all three gossip modes.
  sdm_variants  — the fused 2-buffer layout, DC-DSGD (theta pinned via
                  the registry derivation), TIME-VARYING random-matching
                  sequences (dense + packed), heterogeneous per-node p.
  baselines     — full-state DSGD (incl. a time-varying sequence),
                  gradient-push on DIRECTED graphs (push-sum
                  de-biasing), and allreduce.
  compressed    — the Compressor layer: error-compensated compressed
                  gradient-push (bernoulli/fixedk payloads over
                  dring/der via the generic exchange_payload transport),
                  the int8 QSGD quantizer (sdm + push-sum), and
                  heterogeneous per-node p in fixed-k mode
                  (pad-to-max-k payloads).

  plane         — the WIRE-PLANE tentpole: a multi-leaf parameter tree
                  compiles to exactly R collective-permutes per exchange
                  (leaf-count-independent), and the static wire-bit
                  accounting equals the HLO payload bits, including the
                  packed sub-byte qsgd u8 wire.

Packed cases additionally assert the wire payload stays at the fixed-k
fraction OF THE WIRE PLANE regardless of graph degree (max-k across
nodes for het-p), and that sender index sets come from the per-step
BATCHED draw (sort count bounded by schedules, not by shift rounds or
leaf count). Compressed-payload cases assert the largest single
collective-permute payload stays at the compressed bit size (k*32 for
fixed-k values, bits/coord — u8-packed below a byte — for qsgd).
"""
import pathlib
import subprocess
import sys

import pytest

HELPER = pathlib.Path(__file__).parent / "helpers" / "method_parity_check.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


def _run_group(group: str) -> list[dict]:
    out = subprocess.run(
        [sys.executable, str(HELPER), group], capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    cases = []
    for line in out.stdout.splitlines():
        if not line.startswith("CASE "):
            continue
        toks = line.split()
        case = {"id": toks[1]}
        for k, v in zip(toks[2::2], toks[3::2]):
            case[k] = v
        cases.append(case)
    assert cases, out.stdout
    return cases


@pytest.mark.parametrize("group", ["sdm_core", "sdm_variants", "baselines",
                                   "compressed", "time_varying", "plane"])
def test_method_parity_sweep(group):
    cases = _run_group(group)
    for c in cases:
        err, scale = float(c["MAXERR"]), float(c["SCALE"])
        assert scale > 0.01, c           # the run actually moved
        # quantizer cases tolerate one stochastic-rounding threshold flip
        # (different f32 reduction orders for the norm can flip a level;
        # the resulting O(norm/levels) delta is not algorithmic drift)
        tol = 1e-3 if "qsgd" in c["id"] else 1e-4
        assert err < tol * max(scale, 1.0), c
        if not c["id"].startswith("allreduce"):
            assert c["HAS_CPERM"] == "True", c
        if "WIRE_ELEMS" in c:
            assert c["WIRE_ELEMS"] == c["EXPECTED_WIRE_ELEMS"], c
            assert int(c["SORT_COUNT"]) <= int(c["MAX_SORTS"]), c
        if "WIRE_BITS" in c:
            # compressed payloads: biggest single permute stays at the
            # compressed size (<= p * dense + the separate index leaf)
            assert 0 < int(c["WIRE_BITS"]) <= int(c["MAX_WIRE_BITS"]), c
        if "ORACLE_MAXERR" in c:
            # the acceptance oracle: the time-varying SDM reference is
            # bit-comparable to an EXPLICIT dense W(t) simulator
            assert float(c["ORACLE_MAXERR"]) <= 1e-6, c
        if "MASS_ERR" in c:
            # compressed push-sum on B-connected sequences: sum x / sum w
            # conserved at every step; de-biased estimates reach the mean
            assert float(c["MASS_ERR"]) < 1e-4, c
            assert float(c["Z_ERR"]) < 0.05, c
        if "ACC_ELEMS" in c:
            # per-link schedule-aware accounting == independent
            # re-derivation from the sequence's union/round degrees...
            assert c["ACC_ELEMS"] == c["EXPECTED_ACC_ELEMS"], c
            # ...and the HLO carries the payload over exactly one
            # collective-permute per union round (switch-free delivery)
            assert int(c["PAYLOAD_PERMS"]) == int(c["UNION_ROUNDS"]), c
        if "CPERM" in c:
            # the wire-plane tentpole: exactly R collective-permutes per
            # exchange in the compiled step, independent of leaf count
            assert int(c["N_LEAVES"]) > 1, c
            assert int(c["CPERM"]) == int(c["EXPECTED_CPERM"]), c
        if "HLO_BITS" in c:
            # static wire-bit accounting == HLO payload bits per step
            # (value-payload transports, incl. packed sub-byte qsgd)
            assert int(c["HLO_BITS"]) == int(c["ACC_BITS"]) > 0, c
