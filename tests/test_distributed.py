"""Distributed (shard_map + ppermute) path == dense-W reference, bit-close.

Runs in a subprocess because XLA_FLAGS device-count faking must happen
before jax initializes (the main test process keeps 1 device).
"""
import pathlib
import re
import subprocess
import sys

import pytest

HELPER = pathlib.Path(__file__).parent / "helpers" / "dist_equiv_check.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


def _run(mode: str) -> dict:
    out = subprocess.run(
        [sys.executable, str(HELPER), mode], capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    vals = dict(re.findall(r"^(\w+) (.+)$", out.stdout, re.M))
    return vals


@pytest.mark.parametrize("mode", ["bernoulli", "fixedk_packed",
                                  "fixedk_rows"])
def test_distributed_matches_reference(mode):
    vals = _run(mode)
    err, scale = float(vals["MAXERR"]), float(vals["SCALE"])
    assert scale > 0.01  # the run actually moved
    assert err < 1e-4 * max(scale, 1.0), (err, scale)
    assert vals["HAS_CPERM"] == "True"
    # the fused 2-buffer step is the same algorithm (half-step shifted)
    assert float(vals["MAXERR_FUSED"]) < 1e-4 * max(scale, 1.0), vals
