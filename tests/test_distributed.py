"""Distributed (shard_map + ppermute) path == dense-W reference, bit-close.

Runs in a subprocess because XLA_FLAGS device-count faking must happen
before jax initializes (the main test process keeps 1 device).

The ring cases are the historical regression anchor; the torus/ER/star
cases exercise the PermuteSchedule generalization (ISSUE 1): reference
and mesh trajectories must agree on any static topology, for dense
(bernoulli) and packed payloads alike, and packed wire payloads must
stay at the fixed-k fraction regardless of graph degree.
"""
import pathlib
import re
import subprocess
import sys

import pytest

HELPER = pathlib.Path(__file__).parent / "helpers" / "dist_equiv_check.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


def _run(mode: str, topo: str = "ring8") -> dict:
    out = subprocess.run(
        [sys.executable, str(HELPER), mode, topo], capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    vals = dict(re.findall(r"^(\w+) (.+)$", out.stdout, re.M))
    return vals


def _check(vals: dict) -> None:
    err, scale = float(vals["MAXERR"]), float(vals["SCALE"])
    assert scale > 0.01  # the run actually moved
    assert err < 1e-4 * max(scale, 1.0), (err, scale)
    assert vals["HAS_CPERM"] == "True"
    # the fused 2-buffer step is the same algorithm (half-step shifted)
    assert float(vals["MAXERR_FUSED"]) < 1e-4 * max(scale, 1.0), vals
    if "WIRE_ELEMS" in vals:
        assert vals["WIRE_ELEMS"] == vals["EXPECTED_WIRE_ELEMS"], vals


@pytest.mark.parametrize("mode", ["bernoulli", "fixedk_packed",
                                  "fixedk_rows"])
def test_distributed_matches_reference(mode):
    _check(_run(mode))


@pytest.mark.parametrize("topo", ["torus2x2", "er8", "star4"])
@pytest.mark.parametrize("mode", ["bernoulli", "fixedk_packed"])
def test_arbitrary_topology_matches_reference(mode, topo):
    _check(_run(mode, topo))
