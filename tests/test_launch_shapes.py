"""input_specs / skip_reason coverage for every (arch x shape)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import shapes as shapes_mod


ALL = sorted(configs.ALIASES)


def test_shape_table_matches_assignment():
    s = shapes_mod.SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


@pytest.mark.parametrize("arch", ALL)
def test_long500k_eligibility(arch):
    cfg = configs.get_config(arch)
    reason = shapes_mod.skip_reason(cfg, shapes_mod.SHAPES["long_500k"])
    if arch in ("rwkv6-3b", "jamba-v0.1-52b", "gemma2-2b"):
        assert reason is None
    else:
        assert reason is not None  # documented skip


@pytest.mark.parametrize("arch", ALL)
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_input_specs_structure(arch, shape):
    cfg = configs.get_config(arch)
    case = shapes_mod.SHAPES[shape]
    specs = shapes_mod.input_specs(cfg, case)
    if case.kind == "train":
        assert specs["tokens"].shape == (case.global_batch, case.seq_len)
        assert specs["labels"].dtype == jnp.int32
    elif case.kind == "prefill":
        assert specs["tokens"].shape == (case.global_batch, case.seq_len)
        assert "cache" in specs
    else:
        assert specs["token"].shape == (case.global_batch,)
        # cache covers the full context length
        if not cfg.is_attention_free:
            kv = [l for l in jax.tree.leaves(specs["cache"])
                  if hasattr(l, "shape") and len(l.shape) == 5
                  and l.shape[2] > 1000]  # KVCache, not rwkv/mamba states
            assert kv and kv[0].shape[2] == case.seq_len
    # modality stubs present exactly for audio/vlm
    assert ("context" in specs) == (cfg.family in ("audio", "vlm"))
    # every leaf is a ShapeDtypeStruct (no allocation)
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, (jax.ShapeDtypeStruct, jax.Array)) and \
            not isinstance(leaf, jax.Array)


def test_all_40_pairs_enumerated():
    """10 archs x 4 shapes = 40; 33 runnable + 7 documented skips."""
    runnable, skipped = 0, 0
    for arch in ALL:
        cfg = configs.get_config(arch)
        for case in shapes_mod.SHAPES.values():
            if shapes_mod.skip_reason(cfg, case) is None:
                runnable += 1
            else:
                skipped += 1
    assert runnable + skipped == 40
    assert runnable == 33 and skipped == 7
