"""MeshRules / logical-axis sharding unit tests (single device: specs only)."""
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.sharding import MeshRules, logical
from repro.train.steps import INNER_RULES, outer_rules, serving_rules


def _mesh(shape=(1, 1), names=("data", "model")):
    # AbstractMesh: spec construction without real devices
    return compat.abstract_mesh(shape, names)


def test_spec_basic_mapping():
    rules = MeshRules(_mesh(), {"batch": "data", "mlp": "model"})
    assert rules.spec(("batch", None, "mlp"), (8, 4, 16)) == \
        P("data", None, "model")


def test_spec_divisibility_fallback():
    rules = MeshRules(_mesh((2, 4)), {"heads": "model"})
    # 6 heads % 4 != 0 -> replicated
    assert rules.spec(("heads",), (6,)) == P()
    assert rules.spec(("heads",), (8,)) == P("model")


def test_spec_each_mesh_axis_used_once():
    rules = MeshRules(_mesh((2, 4)), {"a": "model", "b": "model"})
    # second use of 'model' in one spec must fall back to None
    assert rules.spec(("a", "b"), (8, 8)) == P("model")


def test_spec_tuple_axes():
    rules = MeshRules(_mesh((2, 2, 2), ("pod", "data", "model")),
                      {"batch": ("pod", "data")})
    assert rules.spec(("batch",), (8,)) == P(("pod", "data"))
    # non-divisible by 4 -> replicate
    assert rules.spec(("batch",), (6,)) == P()


def test_missing_mesh_axis_is_ignored():
    rules = MeshRules(_mesh((2,), ("data",)), {"mlp": "model"})
    assert rules.spec(("mlp",), (8,)) == P()


def test_logical_noop_without_rules():
    x = jnp.ones((4, 4))
    assert logical(x, "batch", "embed") is x


def test_rule_tables_cover_model_axes():
    for name in ("heads_flat", "kv_flat", "mlp", "vocab", "experts"):
        assert INNER_RULES[name] == "model"
    r = outer_rules(("pod", "data"))
    assert r["batch"] == ("pod", "data")
    r1 = serving_rules(("data",), shard_cache_seq=False, decode=True)
    assert r1["cache_seq"] == "model"
    r2 = serving_rules(("data",), shard_cache_seq=True, decode=True)
    assert r2["cache_seq"] == ("data", "model") and r2["batch"] is None
