"""Soundness of the certifier's abstract domains (property-based).

The sensitivity pass is only as good as its transfer functions: every
rule in ``repro.analysis.sensitivity`` claims "if each input coordinate
is bounded by beta_in, each output coordinate is bounded by f(beta_in)".
These tests drive the EXACT module-level transfer functions the
interpreter calls against concrete random inputs and assert domination:
abstract bound >= concrete magnitude, always.

Same story for the two other layers of the certificate:

* ``Interval`` arithmetic: each operation's result interval contains
  the pointwise result of any member points (the integer-range chain is
  a composition of these);
* ``Compressor.coord_sensitivity_transfer``: the declared worst-case
  coordinate inflation dominates a concrete compress->decompress
  roundtrip for every registered family (the analyzer contract the
  certificate's ``coord_inflation_at_c`` column relies on).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import sensitivity
from repro.core import clipping, compressor

_TOL = 1e-5


def _bounded(seed: int, n: int, beta: float) -> np.ndarray:
    """A random vector with every |coordinate| <= beta (hits the bound)."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(-beta, beta, size=n)
    if n:
        x[rng.integers(n)] = beta * rng.choice((-1.0, 1.0))
    return x.astype(np.float32)


# ------------------------------------------------------- norm-bound transfer

@settings(max_examples=50)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 64),
       beta=st.floats(0.0, 8.0), c=st.floats(1e-3, 4.0))
def test_clip_transfer_dominates(seed, n, beta, c):
    x = _bounded(seed, n, beta)
    out = np.asarray(clipping.clip_coordinates(jnp.asarray(x), c))
    bound = sensitivity.clip_transfer(beta, c)
    assert np.abs(out).max() <= bound * (1.0 + _TOL) + 1e-7


@settings(max_examples=50)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 64),
       ba=st.floats(0.0, 8.0), bb=st.floats(0.0, 8.0))
def test_add_transfer_dominates(seed, n, ba, bb):
    a, b = _bounded(seed, n, ba), _bounded(seed + 1, n, bb)
    assert np.abs(a + b).max() <= \
        sensitivity.add_transfer(ba, bb) * (1.0 + _TOL) + 1e-7


@settings(max_examples=50)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 64),
       beta=st.floats(0.0, 8.0), c=st.floats(-4.0, 4.0))
def test_scale_transfer_dominates(seed, n, beta, c):
    x = _bounded(seed, n, beta)
    assert np.abs(x * c).max() <= \
        sensitivity.scale_transfer(beta, c) * (1.0 + _TOL) + 1e-7


@settings(max_examples=50)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 32),
       ba=st.floats(0.0, 8.0), bb=st.floats(0.0, 8.0),
       pad_lo=st.integers(0, 3), pad_hi=st.integers(0, 3))
def test_concat_and_pad_transfer_dominate(seed, n, ba, bb, pad_lo, pad_hi):
    a, b = _bounded(seed, n, ba), _bounded(seed + 1, n, bb)
    cat = np.concatenate([a, b])
    assert np.abs(cat).max() <= \
        sensitivity.concat_transfer(ba, bb) * (1.0 + _TOL) + 1e-7
    padded = np.asarray(jnp.pad(jnp.asarray(a), (pad_lo, pad_hi)))
    assert np.abs(padded).max() <= \
        sensitivity.pad_transfer(ba, 0.0) * (1.0 + _TOL) + 1e-7


@settings(max_examples=50)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 64),
       beta=st.floats(0.0, 8.0))
def test_reduce_sum_transfer_dominates(seed, n, beta):
    x = _bounded(seed, n, beta)
    assert abs(float(x.sum())) <= \
        sensitivity.reduce_sum_transfer(beta, n) * (1.0 + _TOL) + 1e-6


# --------------------------------------------------------- Interval algebra

@settings(max_examples=50)
@given(a_lo=st.floats(-50.0, 50.0), a_w=st.floats(0.0, 20.0),
       b_lo=st.floats(-50.0, 50.0), b_w=st.floats(0.0, 20.0),
       ta=st.floats(0.0, 1.0), tb=st.floats(0.0, 1.0),
       c=st.floats(-8.0, 8.0), lo=st.floats(-10.0, 0.0),
       hi=st.floats(0.0, 10.0))
def test_interval_ops_contain_pointwise_results(a_lo, a_w, b_lo, b_w,
                                                ta, tb, c, lo, hi):
    A = sensitivity.Interval(a_lo, a_lo + a_w)
    B = sensitivity.Interval(b_lo, b_lo + b_w)
    x = a_lo + ta * a_w                        # arbitrary members
    y = b_lo + tb * b_w

    def inside(iv, v):
        return iv.lo - 1e-9 <= v <= iv.hi + 1e-9

    assert inside(A.add(B), x + y)
    assert inside(A.scale(c), x * c)
    assert inside(A.clamp(lo, hi), min(max(x, lo), hi))
    assert inside(A.join(B), x) and inside(A.join(B), y)


@settings(max_examples=30)
@given(bits=st.sampled_from([2, 4]), seed=st.integers(0, 2**31 - 1))
def test_interval_or_disjoint_is_exact_for_packed_fields(bits, seed):
    """OR of disjoint bit fields == ADD, the sub-byte pack's invariant."""
    rng = np.random.default_rng(seed)
    k = 8 // bits
    fields = rng.integers(0, 2 ** bits, size=k)
    byte_or, byte_add = 0, 0
    iv = sensitivity.Interval(0.0, 0.0)
    for j, f in enumerate(fields):
        byte_or |= int(f) << (j * bits)
        byte_add += int(f) << (j * bits)
        iv = iv.or_disjoint(
            sensitivity.Interval(0.0, float(2 ** bits - 1))
            .shift_left(j * bits))
    assert byte_or == byte_add
    assert iv.lo <= byte_or <= iv.hi <= 255.0


def test_interval_or_disjoint_rejects_signed_fields():
    with pytest.raises(ValueError):
        sensitivity.Interval(-1.0, 3.0).or_disjoint(
            sensitivity.Interval(0.0, 3.0))


# ------------------------------------------- quantizer interval containment

@settings(max_examples=20)
@given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31 - 1),
       fused=st.booleans())
def test_qsgd_wire_values_stay_in_certified_range(bits, seed, fused):
    if fused:
        comp = compressor.FusedQSGDCompressor(p=1.0, bits=bits)
        shape = (2, 8)                       # lane-divisible plane
    else:
        comp = compressor.QSGDCompressor(p=1.0, bits=bits)
        shape = (16,)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=shape) * 3.0, jnp.float32)
    cert = sensitivity.qsgd_range_certificate(
        bits, fused=fused, plane_elems=int(np.prod(shape)))
    assert cert["findings"] == []
    payload = comp.compress(jax.random.PRNGKey(seed % 997), x)
    vals = np.asarray(payload.values).astype(np.int64)
    body = vals[:-4] if fused else vals      # fused: drop norm tail bytes
    lo, hi = cert["byte_range"]
    assert body.min() >= lo and body.max() <= hi, (bits, fused)
    # and the roundtrip coordinate never exceeds the declared transfer
    out = np.asarray(comp.decompress(payload))
    beta = float(np.abs(np.asarray(x)).max())
    bound = comp.coord_sensitivity_transfer(beta, shape)
    assert np.abs(out).max() <= bound * (1.0 + 1e-5)


# ------------------------------------- compressor transfer declarations

@settings(max_examples=20)
@given(spec=st.sampled_from(["bernoulli", "fixedk", "rows", "qsgd:4",
                             "qsgdf:4"]),
       seed=st.integers(0, 2**31 - 1), p=st.floats(0.1, 0.9))
def test_coord_sensitivity_transfer_dominates_roundtrip(spec, seed, p):
    comp = compressor.make(spec, p=p)
    shape = (4, 8)
    rng = np.random.default_rng(seed)
    beta = float(rng.uniform(0.1, 2.0))
    x = jnp.asarray(_bounded(seed, int(np.prod(shape)), beta)
                    .reshape(shape))
    payload = comp.compress(jax.random.PRNGKey(seed % 997), x)
    out = np.asarray(comp.decompress(payload))
    bound = comp.coord_sensitivity_transfer(beta, shape)
    assert math.isfinite(bound)
    assert np.abs(out).max() <= bound * (1.0 + 1e-4), (spec, p)


def test_base_transfer_is_conservative():
    class Opaque(compressor.Compressor):
        pass

    comp = Opaque(p=0.5)
    assert comp.coord_sensitivity_transfer(1.0, (8,)) == math.inf
    assert comp.coord_sensitivity_transfer(0.0, (8,)) == 0.0
