"""End-to-end trainer integration: the paper's loop on a small testbed."""
import jax
import jax.numpy as jnp

from repro.core import PrivacyParams, SDMConfig, sdm_dsgd, topology
from repro.data import classification_dataset, node_partitioned_batches
from repro.models import vision_small
from repro.train.trainer import run_decentralized

N = 6


def _testbed(features=32, classes=4, n_train=1200, seed=0):
    topo = topology.ring(N)
    (xtr, ytr), (xte, yte) = classification_dataset(features, classes,
                                                    n_train, 400, seed=seed)
    p0 = vision_small.mlr_init(jax.random.PRNGKey(seed), features, classes)
    stack = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (N,) + p.shape), p0)
    grad_fn = vision_small.make_stacked_grad_fn(vision_small.mlr_apply)
    eval_fn = vision_small.make_eval_fn(vision_small.mlr_apply,
                                        jnp.asarray(xte), jnp.asarray(yte))
    batches = node_partitioned_batches(xtr, ytr, N, 16, seed=seed)
    return topo, stack, grad_fn, eval_fn, batches


def test_sdm_training_improves_accuracy_and_tracks_privacy(tmp_path):
    topo, stack, grad_fn, eval_fn, batches = _testbed()
    cfg = SDMConfig(p=0.3, theta=0.3, gamma=0.1, sigma=1.0, clip_c=5.0)
    cfg.validate_against(topo)
    pp = PrivacyParams(G=5.0, m=200, tau=16 / 200, p=0.3, sigma=1.0)
    res = run_decentralized(
        topo=topo, algorithm="sdm_dsgd", sdm_cfg=cfg, params_stack=stack,
        grad_fn=grad_fn, batches=batches, steps=120, privacy=pp,
        eps_target=1.0, eval_fn=eval_fn, eval_every=40,
        checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=60)
    assert res.losses[-1] < res.losses[0]
    assert res.eval_accuracy[-1] > 0.5          # well above 0.25 chance
    # privacy epsilon accumulates monotonically
    assert all(b >= a for a, b in zip(res.epsilons, res.epsilons[1:]))
    # comm metric is per-link and schedule-aware: p * wire-plane size per
    # payload (the transport compresses the padded (rows, LANE) plane),
    # one payload per out-edge (the symmetric ring has out-degree 2),
    # exact Fraction arithmetic rounded once
    from fractions import Fraction
    from repro.core import plane
    d = plane.ParamPlane.for_tree(
        jax.tree.map(lambda x: x[0], stack)).padded_size
    assert res.comm_elements[0] == round(Fraction("0.3") * d * 2) * N
    # checkpoints written
    import os
    assert len(os.listdir(tmp_path / "ck")) == 2


def test_dsgd_and_dcdsgd_paths():
    topo, stack, grad_fn, eval_fn, batches = _testbed(seed=1)
    from repro.core import baselines
    res1 = run_decentralized(
        topo=topo, algorithm="dsgd",
        sdm_cfg=SDMConfig(p=1.0, theta=1.0, gamma=0.1),
        params_stack=stack, grad_fn=grad_fn, batches=batches, steps=80)
    res2 = run_decentralized(
        topo=topo, algorithm="dc_dsgd",
        sdm_cfg=baselines.dcdsgd_config(p=0.8, gamma=0.1),
        params_stack=stack, grad_fn=grad_fn, batches=batches, steps=80)
    assert res1.losses[-1] < res1.losses[0]
    assert res2.losses[-1] < res2.losses[0]
    # DSGD sends the full model (as its padded wire plane) on both ring
    # out-edges every step
    from repro.core import plane
    d = plane.ParamPlane.for_tree(
        jax.tree.map(lambda x: x[0], stack)).padded_size
    assert res1.comm_elements[0] == d * 2 * N
