"""Production train-step factory executes on a fake 2x2 mesh for all four
algorithms (sdm_dsgd / fused / dsgd / allreduce) and losses decrease."""
import pathlib
import subprocess
import sys

HELPER = pathlib.Path(__file__).parent / "helpers" / "train_step_mesh_check.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


def test_all_algorithms_train_on_mesh():
    out = subprocess.run(
        [sys.executable, str(HELPER)], capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
        timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    for algo in ("sdm_dsgd", "sdm_dsgd_fused", "dsgd", "allreduce"):
        assert f"ALGO_OK {algo}" in out.stdout, out.stdout
