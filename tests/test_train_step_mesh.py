"""Production train-step factory executes on a fake 2x2 mesh for every
registered method (sdm-dsgd / fused / dc-dsgd / dsgd / gradient-push /
allreduce) and losses decrease."""
import pathlib
import subprocess
import sys

HELPER = pathlib.Path(__file__).parent / "helpers" / "train_step_mesh_check.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


def test_all_methods_train_on_mesh():
    out = subprocess.run(
        [sys.executable, str(HELPER)], capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
        timeout=1800)
    assert out.returncode == 0, out.stderr[-3000:]
    for algo in ("sdm_dsgd", "sdm_dsgd_fused", "dsgd", "allreduce",
                 "gradient-push", "dc-dsgd"):
        assert f"ALGO_OK {algo}" in out.stdout, out.stdout
